#!/usr/bin/env python
"""Docstring lint for the public engine/optimizer/distributed surface.

Fails (exit 1) when a Python file under ``src/repro/{core,optim,distributed}``
contains a *public* function, method, or class without a docstring, or a
module without a module docstring. Public means the name has no leading
underscore; nested (closure) functions — e.g. the planners' inner ``plan``
or optimizer ``init``/``update`` closures — are exempt, as are dunder
methods and NamedTuple/dataclass field-only bodies.

Run from the repo root (CI docs job does):

    python tools/check_docstrings.py [--root src/repro] [pkg ...]
"""

from __future__ import annotations

import argparse
import ast
import sys
from pathlib import Path

DEFAULT_PKGS = ("core", "optim", "distributed")


def _is_fieldonly_class(node: ast.ClassDef) -> bool:
    """True for bodies that are only field annotations / assignments
    (NamedTuple-style records read fine without a docstring)."""
    return all(isinstance(s, (ast.AnnAssign, ast.Assign, ast.Pass)) for s in node.body)


def check_file(path: Path) -> list[str]:
    """Return human-readable violations for one Python file."""
    tree = ast.parse(path.read_text(), filename=str(path))
    out = []
    if ast.get_docstring(tree) is None:
        out.append(f"{path}:1 module lacks a docstring")

    def visit(node, prefix: str) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                name = child.name
                if not name.startswith("_") and ast.get_docstring(child) is None:
                    out.append(f"{path}:{child.lineno} public "
                               f"{'method' if prefix else 'function'} "
                               f"{prefix}{name} lacks a docstring")
                # do NOT recurse: nested closures are implementation detail
            elif isinstance(child, ast.ClassDef):
                if not child.name.startswith("_"):
                    if ast.get_docstring(child) is None and not _is_fieldonly_class(child):
                        out.append(f"{path}:{child.lineno} public class "
                                   f"{child.name} lacks a docstring")
                    visit(child, f"{child.name}.")

    visit(tree, "")
    return out


def main() -> int:
    """Lint all requested packages; print violations and return exit code."""
    ap = argparse.ArgumentParser()
    ap.add_argument("pkgs", nargs="*", default=list(DEFAULT_PKGS),
                    help=f"packages under --root to lint (default: {DEFAULT_PKGS})")
    ap.add_argument("--root", default="src/repro")
    args = ap.parse_args()

    violations: list[str] = []
    for pkg in args.pkgs or DEFAULT_PKGS:
        base = Path(args.root) / pkg
        if not base.is_dir():
            print(f"error: {base} is not a directory", file=sys.stderr)
            return 2
        for py in sorted(base.rglob("*.py")):
            violations += check_file(py)
    for v in violations:
        print(v)
    if violations:
        print(f"\n{len(violations)} docstring violation(s)", file=sys.stderr)
        return 1
    print("docstring check: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
