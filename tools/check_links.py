#!/usr/bin/env python
"""Markdown link check for docs/ + README.md (CI docs job).

Verifies every relative ``[text](target)`` link resolves to an existing
file or directory (anchors are stripped; ``http(s)``/``mailto`` targets are
skipped so the check stays deterministic offline). Exit 1 on any broken
link.

    python tools/check_links.py [files-or-dirs ...]   # default: docs README.md
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

# [text](target) — excluding images is unnecessary; same resolution rule
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def check_file(md: Path) -> list[str]:
    """Return broken-link messages for one markdown file."""
    out = []
    for i, line in enumerate(md.read_text().splitlines(), 1):
        for target in _LINK_RE.findall(line):
            if re.match(r"^[a-z][a-z0-9+.-]*:", target):  # http:, mailto:, ...
                continue
            path = target.split("#", 1)[0]
            if not path:  # pure in-page anchor
                continue
            if not (md.parent / path).exists():
                out.append(f"{md}:{i} broken link -> {target}")
    return out


def main() -> int:
    """Check all markdown files under the given paths (default docs/ + README)."""
    roots = [Path(p) for p in sys.argv[1:]] or [Path("docs"), Path("README.md")]
    files: list[Path] = []
    for r in roots:
        if r.is_dir():
            files += sorted(r.rglob("*.md"))
        elif r.exists():
            files.append(r)
        else:
            print(f"error: {r} does not exist", file=sys.stderr)
            return 2
    broken: list[str] = []
    for f in files:
        broken += check_file(f)
    for b in broken:
        print(b)
    if broken:
        print(f"\n{len(broken)} broken link(s)", file=sys.stderr)
        return 1
    print(f"link check: OK ({len(files)} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
