"""Run summarizer for --metrics-dir telemetry dumps.

    PYTHONPATH=src python tools/metrics_report.py /tmp/run_metrics_dir
    PYTHONPATH=src python tools/metrics_report.py dir1 dir2 --json out.json

Reads the artifacts a ``launch/train.py --metrics-dir`` or
``launch/serve.py --metrics-dir`` run wrote (``events.jsonl``,
``metrics.json``, ``trace.json``) and prints one human-readable summary
per directory: event counts by name, span-phase wall-time totals,
counters, notable gauges (loss, queue depth, pool utilization, the
largest in-jit ``tel/`` numerics values), and latency histogram quantiles
(TTFT/TPOT, step time). CI runs this over the telemetry-smoke artifacts
so a malformed dump fails the build (exit 1): every directory must hold a
parseable ``events.jsonl`` + ``metrics.json``, and the trace (when
present) must be loadable Chrome ``trace_event`` JSON.
"""

from __future__ import annotations

import argparse
import json
import sys
from collections import Counter
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.obs.registry import Histogram  # noqa: E402


def _load_hist(d: dict) -> Histogram:
    h = Histogram(tuple(d["boundaries"]))
    h.counts = list(d["counts"])
    h.count = d["count"]
    h.sum = d["sum"]
    if d["count"]:
        h.min, h.max = d["min"], d["max"]
    return h


def summarize_dir(path: Path) -> dict:
    """Parse one metrics dir; raises on malformed/missing artifacts."""
    events_p = path / "events.jsonl"
    metrics_p = path / "metrics.json"
    if not events_p.exists():
        raise FileNotFoundError(f"{events_p}: no event log")
    if not metrics_p.exists():
        raise FileNotFoundError(f"{metrics_p}: no metrics snapshot")

    records = []
    for i, line in enumerate(events_p.read_text().splitlines()):
        if not line.strip():
            continue
        rec = json.loads(line)
        if "kind" not in rec or "name" not in rec or "t" not in rec:
            raise ValueError(f"{events_p}:{i + 1}: record missing kind/name/t")
        records.append(rec)
    snap = json.loads(metrics_p.read_text())
    for section in ("counters", "gauges", "histograms"):
        if section not in snap:
            raise ValueError(f"{metrics_p}: snapshot missing {section!r}")

    trace_p = path / "trace.json"
    trace_events = None
    if trace_p.exists():
        doc = json.loads(trace_p.read_text())
        if "traceEvents" not in doc:
            raise ValueError(f"{trace_p}: not a Chrome trace_event document")
        trace_events = len(doc["traceEvents"])

    events = Counter(r["name"] for r in records if r["kind"] == "event")
    span_ms: dict[str, float] = {}
    span_n: Counter = Counter()
    for r in records:
        if r["kind"] == "span":
            span_ms[r["name"]] = span_ms.get(r["name"], 0.0) + r["dur_ms"]
            span_n[r["name"]] += 1

    out = {
        "dir": str(path),
        "records": len(records),
        "trace_events": trace_events,
        "events": dict(events),
        "spans": {k: {"count": span_n[k], "total_ms": round(v, 3)}
                  for k, v in sorted(span_ms.items())},
        "counters": snap["counters"],
        "histograms": {},
    }
    for name, hd in sorted(snap["histograms"].items()):
        h = _load_hist(hd)
        out["histograms"][name] = {
            "count": h.count,
            "mean": None if not h.count else round(h.mean(), 4),
            "p50": None if not h.count else round(h.quantile(0.5), 4),
            "p99": None if not h.count else round(h.quantile(0.99), 4),
            "max": None if not h.count else round(h.max, 4),
        }
    # notable gauges: loss/queue/pool always; in-jit numerics (tel/*) by
    # largest magnitude — the counters most likely to flag drift
    gauges = snap["gauges"]
    keep = {k: v for k, v in gauges.items() if not k.startswith("tel/")}
    tel = sorted(((k, v) for k, v in gauges.items() if k.startswith("tel/")),
                 key=lambda kv: -abs(kv[1]))
    out["gauges"] = dict(sorted(keep.items()))
    out["top_telemetry"] = dict(tel[:10])
    out["telemetry_gauges"] = len(tel)
    return out


def print_summary(s: dict) -> None:
    print(f"== {s['dir']} ==")
    print(f"  records: {s['records']} "
          f"(trace: {s['trace_events'] if s['trace_events'] is not None else 'n/a'})")
    if s["events"]:
        print("  events: " + ", ".join(
            f"{k}={v}" for k, v in sorted(s["events"].items())))
    for name, sp in s["spans"].items():
        print(f"  span {name}: {sp['count']}x, {sp['total_ms']:.1f} ms total")
    for name, v in sorted(s["counters"].items()):
        print(f"  counter {name} = {v:g}")
    for name, v in s["gauges"].items():
        print(f"  gauge {name} = {v:g}")
    for name, h in s["histograms"].items():
        if h["count"]:
            print(f"  hist {name}: n={h['count']} mean={h['mean']} "
                  f"p50={h['p50']} p99={h['p99']} max={h['max']}")
    if s["telemetry_gauges"]:
        print(f"  in-jit telemetry: {s['telemetry_gauges']} gauges; largest:")
        for k, v in s["top_telemetry"].items():
            print(f"    {k} = {v:g}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("dirs", nargs="+", help="--metrics-dir paths to summarize")
    ap.add_argument("--json", default=None,
                    help="also write the summaries as JSON to this path")
    args = ap.parse_args(argv)

    summaries = []
    status = 0
    for d in args.dirs:
        try:
            s = summarize_dir(Path(d))
        except (OSError, ValueError, KeyError) as e:
            print(f"FAIL {d}: {e}", file=sys.stderr)
            status = 1
            continue
        summaries.append(s)
        print_summary(s)
    if args.json:
        Path(args.json).write_text(json.dumps(summaries, indent=2) + "\n")
    return status


if __name__ == "__main__":
    sys.exit(main())
