#!/usr/bin/env python
"""spec-lint: every shipped OptimizerSpec must JSON-round-trip losslessly.

Checks, for every arch default spec (``repro.configs.default_optimizer_spec``
over PAPER_IDS + ARCH_IDS), every spec declared by the dry-run launcher per
(arch, --opt) pair, and every module-level spec constant in ``examples/*.py``
(attributes named ``SPEC`` or dict ``SPECS``):

* ``OptimizerSpec.from_json(spec.to_json()) == spec`` (identity);
* ``spec_hash()`` is stable across the round-trip (checkpoint-resume
  depends on this);
* ``build_optimizer(spec)`` constructs (hyperparams validate against the
  family registry).

Plus one knob check: a spec declaring the execution-only ``telemetry``
hyperparam round-trips and its ``spec_hash`` is neutral to the flag's
value (flipping observability must never re-key checkpoints).

Run from the repo root (CI docs job does):

    PYTHONPATH=src python tools/spec_lint.py
"""

from __future__ import annotations

import importlib.util
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))


def _check(label: str, spec) -> list[str]:
    """Round-trip + hash-stability + buildability violations for one spec."""
    from repro.optim.spec import OptimizerSpec, build_optimizer

    out = []
    try:
        text = spec.to_json()
    except ValueError as e:
        return [f"{label}: not serializable: {e}"]
    back = OptimizerSpec.from_json(text)
    if back != spec:
        out.append(f"{label}: from_json(to_json(spec)) != spec")
    if back.spec_hash() != spec.spec_hash():
        out.append(f"{label}: spec_hash unstable across round-trip")
    try:
        build_optimizer(spec)
    except Exception as e:  # noqa: BLE001 - lint surface, report everything
        out.append(f"{label}: build_optimizer failed: {e!r}")
    return out


def _example_specs():
    """(label, spec) for every SPEC/SPECS constant in examples/*.py."""
    from repro.optim.spec import OptimizerSpec

    for path in sorted((ROOT / "examples").glob("*.py")):
        name = f"_speclint_{path.stem}"
        mspec = importlib.util.spec_from_file_location(name, path)
        mod = importlib.util.module_from_spec(mspec)
        mspec.loader.exec_module(mod)
        one = getattr(mod, "SPEC", None)
        if isinstance(one, OptimizerSpec):
            yield f"examples/{path.name}:SPEC", one
        many = getattr(mod, "SPECS", None)
        if isinstance(many, dict):
            for k, v in many.items():
                if isinstance(v, OptimizerSpec):
                    yield f"examples/{path.name}:SPECS[{k}]", v


def main() -> int:
    """Lint all shipped specs; print violations and return the exit code."""
    from repro.configs import ARCH_IDS, PAPER_IDS, default_optimizer_spec, get_config
    from repro.launch.dryrun import cell_optimizer_spec

    violations: list[str] = []
    n = 0
    for arch in PAPER_IDS + ARCH_IDS:
        violations += _check(f"configs:{arch} default", default_optimizer_spec(arch))
        n += 1
        for opt_name in ("smmf", "smmf_local", "adam", "adafactor",
                         "adapprox", "hfac"):
            spec = cell_optimizer_spec(get_config(arch), opt_name)
            violations += _check(f"dryrun:{arch}:{opt_name}", spec)
            n += 1
        # quantized-state specs (the qstate codec) must round-trip too —
        # quant is layout-relevant, so the hash must be stable across JSON
        for quant in ("int8", "fp8"):
            spec = cell_optimizer_spec(get_config(arch), "smmf", quant=quant)
            violations += _check(f"dryrun:{arch}:smmf.{quant}", spec)
            n += 1
    # execution-only knobs (telemetry, use_kernel, transport, ...) must
    # survive the JSON round-trip as declared hyperparams while staying
    # spec_hash-neutral: flipping one must not re-key checkpoints
    from repro.optim.spec import OptimizerSpec

    tel_off = OptimizerSpec(family="smmf",
                            hyperparams={"lr": 1e-3, "decay_rate": -0.8,
                                         "telemetry": False})
    tel_on = OptimizerSpec(family="smmf",
                           hyperparams={"lr": 1e-3, "decay_rate": -0.8,
                                        "telemetry": True})
    violations += _check("knob:smmf.telemetry=False", tel_off)
    violations += _check("knob:smmf.telemetry=True", tel_on)
    n += 2
    if tel_on.spec_hash() != tel_off.spec_hash():
        violations.append(
            "knob:smmf.telemetry: spec_hash not neutral — flipping the "
            "execution-only telemetry knob re-keys checkpoints")
    for label, spec in _example_specs():
        violations += _check(label, spec)
        n += 1
    if violations:
        print(f"spec-lint: {len(violations)} violation(s) over {n} specs:")
        for v in violations:
            print("  " + v)
        return 1
    print(f"spec-lint: OK ({n} specs round-tripped, hashed, and built)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
